"""sgd_dw_update: fused dW computation + in-place SGD step.

    W <- q_w( W - lr * (X^T @ G) )           (paper Eq. 9 + Eq. 1, step 4)

The gradient tensor dW = X^T G is accumulated in VMEM across the token
blocks and folded into the weight update in the same kernel — dW never
exists in HBM.  This is the TaxoNN fused-update property (gradient
lifetime = one PE pass) expressed at the memory-hierarchy level that
matters on TPU.

Shapes: X [T, Din], G [T, Dout], W [Din, Dout] -> W_new [Din, Dout].
Grid (Din/bm, Dout/bn, T/bk): the contraction is over tokens.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import kq


def _kernel(x_ref, g_ref, w_ref, lr_ref, o_ref, *, n_k: int, w_bits):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (X block [bk, bm])^T @ G block [bk, bn] -> [bm, bn]
    acc = jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _finish():
        w_new = w_ref[...].astype(jnp.float32) - lr_ref[0] * o_ref[...]
        if w_bits is not None:
            w_new = kq(w_new, *w_bits)
        o_ref[...] = w_new


def sgd_dw_update(x: jax.Array, g: jax.Array, w: jax.Array, lr,
                  *, w_bits=None,
                  bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = False) -> jax.Array:
    """x: [T, Din]; g: [T, Dout]; w: [Din, Dout]; lr scalar.
    Returns W - lr * x^T g (optionally re-quantized to (I,F))."""
    t, din = x.shape
    t2, dout = g.shape
    assert t == t2 and w.shape == (din, dout)
    bm, bn, bk = min(bm, din), min(bn, dout), min(bk, t)
    assert din % bm == 0 and dout % bn == 0 and t % bk == 0
    n_k = t // bk

    lr_arr = jnp.asarray([lr], jnp.float32)
    grid = (din // bm, dout // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, w_bits=w_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),   # X
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # G
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),   # W
            pl.BlockSpec(memory_space=pl.ANY),                # lr (scalar)
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((din, dout), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, g, w, lr_arr)
