"""Fused paged-attention decode kernel: block-table gather + int8 KV
dequant + flash-style softmax in one ``pallas_call``.

One grid step per decode slot.  The block table and per-slot lengths ride
scalar prefetch (``PrefetchScalarGridSpec``) so the kernel can index the
pool before the body runs; the gather loop pulls each of the slot's blocks
out of the VMEM-resident pool with a dynamic slice, dequantizes int8
payloads against their per-token scales on the way, and lands them in a
contiguous [T, kv_heads, head_dim] scratch.  The softmax is single-tile
flash: one max-subtracted exponentiation + normalization over the whole
gathered row (the row fits VMEM by construction — ``ops.tune_paged``
budgets it), computed with the exact op sequence of the jnp reference, so
kernel and ref are BITWISE identical in interpret mode (tested in
tests/test_paging.py).

``paged_attention`` picks kernel vs ref: the kernel when the
``tune_paged`` budget admits the pool, the jnp gather path otherwise.
Shapes the budget rejects are exactly the ones whose pool belongs in HBM —
the multi-pass DMA variant is the TPU-scale follow-up; the ref path keeps
semantics identical meanwhile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ops as kops

NEG_INF = -1e30  # matches models.layers.NEG_INF


def _expand_heads(k, groups: int):
    """[T, Hkv, hd] -> [T, Hkv*groups, hd] (GQA repeat, layers._expand_kv
    order)."""
    if groups == 1:
        return k
    t, hkv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, None, :], (t, hkv, groups, hd))
    return k.reshape(t, hkv * groups, hd)


def _attend(q, kk, vv, length, t, scale):
    """The shared softmax tail: q [1,H,hd]; kk/vv [T,H,hd] (expanded).

    Op-for-op the batched math of ``serving.engine._paged_attention`` with
    B=1, C=1 — the bitwise contract between kernel and ref lives here.
    """
    s = jnp.einsum("bqhd,bkhd->bhqk", q[None], kk[None],
                   preferred_element_type=jnp.float32) * scale
    kpos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, t), 2)
    ok = kpos <= length
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv[None])
    return out[0, 0]  # [H, hd]


def _kernel(tbl_ref, len_ref, q_ref, kp_ref, vp_ref, o_ref, kg_ref, vg_ref,
            *, m: int, bs: int, groups: int, scale: float):
    b = pl.program_id(0)
    dt = q_ref.dtype
    for i in range(m):  # static trip count: max blocks per sequence
        bid = tbl_ref[b, i]
        kb = kp_ref[pl.ds(bid, 1)][0]
        vb = vp_ref[pl.ds(bid, 1)][0]
        kg_ref[pl.ds(i * bs, bs)] = kb.astype(dt)
        vg_ref[pl.ds(i * bs, bs)] = vb.astype(dt)
    kk = _expand_heads(kg_ref[...], groups)
    vv = _expand_heads(vg_ref[...], groups)
    o_ref[...] = _attend(q_ref[...][0][None], kk, vv, len_ref[b],
                         m * bs, scale)[None]


def _kernel_int8(tbl_ref, len_ref, q_ref, kp_ref, vp_ref, ks_ref, vs_ref,
                 o_ref, kg_ref, vg_ref, *, m: int, bs: int, groups: int,
                 scale: float):
    b = pl.program_id(0)
    dt = q_ref.dtype
    for i in range(m):
        bid = tbl_ref[b, i]
        kb = kp_ref[pl.ds(bid, 1)][0]
        vb = vp_ref[pl.ds(bid, 1)][0]
        ks = ks_ref[pl.ds(bid, 1)][0]
        vs = vs_ref[pl.ds(bid, 1)][0]
        kg_ref[pl.ds(i * bs, bs)] = kb.astype(dt) * ks[:, None, None].astype(dt)
        vg_ref[pl.ds(i * bs, bs)] = vb.astype(dt) * vs[:, None, None].astype(dt)
    kk = _expand_heads(kg_ref[...], groups)
    vv = _expand_heads(vg_ref[...], groups)
    o_ref[...] = _attend(q_ref[...][0][None], kk, vv, len_ref[b],
                         m * bs, scale)[None]


@functools.partial(jax.jit, static_argnames=("groups", "scale"))
def _ref(q, pool_l, tables, lens, groups: int, scale: float):
    """jnp gather fallback — the same math the engine's ref branch runs."""
    dt = q.dtype
    kk = pool_l["k"][tables]
    vv = pool_l["v"][tables]
    b, m, bs, hkv, hd = kk.shape
    kk = kk.reshape(b, m * bs, hkv, hd)
    vv = vv.reshape(b, m * bs, hkv, hd)
    if "k_scale" in pool_l:
        ks = pool_l["k_scale"][tables].reshape(b, m * bs)
        vs = pool_l["v_scale"][tables].reshape(b, m * bs)
        kk = kk.astype(dt) * ks[..., None, None].astype(dt)
        vv = vv.astype(dt) * vs[..., None, None].astype(dt)
    else:
        kk = kk.astype(dt)
        vv = vv.astype(dt)
    kk = jax.vmap(_expand_heads, in_axes=(0, None))(kk, groups)
    vv = jax.vmap(_expand_heads, in_axes=(0, None))(vv, groups)
    s = jnp.einsum("bqhd,bkhd->bhqk", q[:, None], kk,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(m * bs)
    ok = kpos[None, None, :] <= lens[:, None, None]
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None]
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    return out[:, 0]


def _call_kernel(q, pool_l, tables, lens, groups: int, scale: float):
    b, h, hd = q.shape
    m = tables.shape[1]
    n, bs, hkv, _ = pool_l["k"].shape
    int8 = "k_scale" in pool_l
    t = m * bs
    interpret = kops._on_cpu()

    def full(x):
        nd = x.ndim
        return pl.BlockSpec(x.shape, lambda i, *_, _nd=nd: (0,) * _nd)

    in_specs = [pl.BlockSpec((1, h, hd), lambda i, *_: (i, 0, 0)),
                full(pool_l["k"]), full(pool_l["v"])]
    args = [q, pool_l["k"], pool_l["v"]]
    if int8:
        body = functools.partial(_kernel_int8, m=m, bs=bs, groups=groups,
                                 scale=scale)
        in_specs += [full(pool_l["k_scale"]), full(pool_l["v_scale"])]
        args += [pool_l["k_scale"], pool_l["v_scale"]]
    else:
        body = functools.partial(_kernel, m=m, bs=bs, groups=groups,
                                 scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, hd), lambda i, *_: (i, 0, 0)),
        scratch_shapes=[pltpu.VMEM((t, hkv, hd), q.dtype),
                        pltpu.VMEM((t, hkv, hd), q.dtype)],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(tables.astype(jnp.int32), lens.astype(jnp.int32), *args)


def paged_attention(q, pool_l: dict, tables, lens, *, groups: int,
                    scale: float):
    """Paged-attention decode for one layer.

    q: [B, H, hd] (post-rope query for the incoming token); pool_l: one
    layer's pool leaves ({"k","v"[,"k_scale","v_scale"]}); tables: [B, M]
    int32 block tables; lens: [B] int32 — the incoming token's position
    (kpos <= lens[b] attends).  Returns [B, H, hd].
    """
    n, bs, hkv, hd = pool_l["k"].shape
    m = tables.shape[1]
    fits = kops.tune_paged(n, bs, m, hkv, hd, groups,
                           itemsize=pool_l["k"].dtype.itemsize)
    if fits is None:
        return _ref(q, pool_l, tables, lens, groups, scale)
    return _call_kernel(q, pool_l, tables, lens, groups, scale)
