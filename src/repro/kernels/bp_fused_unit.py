"""bp_fused_unit: the paper's full TDM frame as ONE kernel pass.

TaxoNN time-multiplexes four slots of the SGD unit onto the inference PE
array; per layer i the frame is:

    G_{i-1} = q_g( (G_i @ q_w(W_i)^T) * f'(Z_{i-1}) )      (Eq. 8)
    dW_i    = X_{i-1}^T @ G_i                              (Eq. 9)
    W_i    <- q_w'( W_i - lr * dW_i )                      (Eq. 1, step 4)

This kernel runs all three in a single ``pallas_call``: one pass over the
token dimension streams G/X/Z blocks through VMEM while W stays resident,
so G_out, dW and W_new share every operand fetch — the fused-update
property (gradient lifetime = one PE pass) with zero HBM round-trips for
the intermediates.

Layout: grid (T/bt,) over token blocks only; W [Din, Dout] and the dW
accumulator are VMEM-resident for the whole frame (sized for the paper's
layer shapes — the autotuner in ops.py falls back to the sequential
kernels when Din*Dout exceeds the VMEM budget).  Per step t:

  * G_out block [bt, Din] = (G block @ W^T) * f'(Z block)   (written out)
  * dW accumulator += X block^T @ G block
  * at the last step: W_new = W - lr * dW                  (written out)

Datapaths: ``emulate`` (f32 MACs, in-kernel kq of W for the G product) and
``int8`` (G/X int8 payloads, W quantized to int8 in-kernel from its static
(I,F) spec; both MACs run int8 x int8 -> int32 with exact wide
accumulators; scales applied once per output).

``double_buffer=True`` streams the three token-block operands (G, X, Z)
HBM -> 2-slot VMEM scratch with explicit prefetch DMAs: frame step k waits
the copies started at step k-1 and starts step k+1's, so the next frame's
operands ride the DMA while the PEs run the current frame's three TDM
slots — the paper's Fig. 3 overlap realised at the memory system.  W stays
VMEM-resident either way.  Numerics identical.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import act_deriv, db_step, int8_dot, maybe_kq
from repro.quant.int8 import int8_spec

# G block [bt, Dout] @ (W [Din, Dout])^T -> [bt, Din]
_GW_DIMS = (((1,), (1,)), ((), ()))
# (X block [bt, Din])^T @ G block [bt, Dout] -> [Din, Dout]
_XG_DIMS = (((0,), (0,)), ((), ()))


def _kernel(g_ref, w_ref, x_ref, z_ref, lr_ref, go_ref, wo_ref, acc_ref,
            wq_ref, *, n_k: int, g_bits, w_bits, w_out_bits, act: str):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # quantize the resident W once per frame (loop-invariant)
        wq_ref[...] = maybe_kq(w_ref[...].astype(jnp.float32), w_bits)

    g = g_ref[...].astype(jnp.float32)

    go = jax.lax.dot_general(g, wq_ref[...], _GW_DIMS,   # backward uses q_w(W)
                             preferred_element_type=jnp.float32)
    go = go * act_deriv(z_ref[...].astype(jnp.float32), act)
    go_ref[...] = maybe_kq(go, g_bits)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), g, _XG_DIMS,
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        w = w_ref[...].astype(jnp.float32)               # master
        wo_ref[...] = maybe_kq(w - lr_ref[0] * acc_ref[...], w_out_bits)


def _kernel_int8(g_ref, w_ref, x_ref, z_ref, meta_ref, go_ref, wo_ref,
                 acc_ref, wq_ref, sw_ref, *, n_k: int, g_bits, w_bits,
                 w_out_bits, act: str, w_spec_static):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # master W -> int8 payload, once per frame (loop-invariant): on its
        # (I,F)-derived grid when the format embeds (w_spec_static), else
        # absmax over the resident W (block-scaled transport of a too-wide
        # format)
        w = w_ref[...].astype(jnp.float32)
        if w_spec_static is not None:
            s_w = jnp.float32(w_spec_static.scale)
            wq_ref[...] = jnp.clip(jnp.round(w / s_w), w_spec_static.qmin,
                                   w_spec_static.qmax).astype(jnp.int8)
        else:
            am = jnp.max(jnp.abs(w))
            s_w = jnp.where(am > 0, am / 127.0, jnp.float32(1.0))
            wq_ref[...] = jnp.clip(jnp.round(w / s_w), -127,
                                   127).astype(jnp.int8)
        sw_ref[0, 0] = s_w

    go = (int8_dot(g_ref[...], wq_ref[...], _GW_DIMS).astype(jnp.float32)
          * (meta_ref[0] * sw_ref[0, 0]))              # s_g * s_w
    go = go * act_deriv(z_ref[...].astype(jnp.float32), act)
    go_ref[...] = maybe_kq(go, g_bits)

    acc_ref[...] += int8_dot(x_ref[...], g_ref[...], _XG_DIMS)

    @pl.when(k == n_k - 1)
    def _finish():
        dw = acc_ref[...].astype(jnp.float32) * meta_ref[1]   # s_x * s_g
        wo_ref[...] = maybe_kq(w_ref[...].astype(jnp.float32)
                               - meta_ref[2] * dw, w_out_bits)


def _db_dmas(g_hbm, x_hbm, z_hbm, gbuf, xbuf, zbuf, sem, bt):
    """Token-block DMA constructors (full-width rows [kk*bt, kk*bt+bt))."""
    def dma(hbm, buf, slot, kk, op):
        return pltpu.make_async_copy(
            hbm.at[pl.ds(kk * bt, bt), :], buf.at[slot], sem.at[op, slot])

    return (lambda s, kk: dma(g_hbm, gbuf, s, kk, 0),
            lambda s, kk: dma(x_hbm, xbuf, s, kk, 1),
            lambda s, kk: dma(z_hbm, zbuf, s, kk, 2))


def _kernel_db(g_hbm, w_ref, x_hbm, z_hbm, lr_ref, go_ref, wo_ref, gbuf,
               xbuf, zbuf, acc_ref, wq_ref, sem, *, n_k: int, bt: int,
               g_bits, w_bits, w_out_bits, act: str):
    k = pl.program_id(0)
    dmas = _db_dmas(g_hbm, x_hbm, z_hbm, gbuf, xbuf, zbuf, sem, bt)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        wq_ref[...] = maybe_kq(w_ref[...].astype(jnp.float32), w_bits)

    slot = db_step(k, n_k, dmas)
    g = gbuf[slot].astype(jnp.float32)

    go = jax.lax.dot_general(g, wq_ref[...], _GW_DIMS,
                             preferred_element_type=jnp.float32)
    go = go * act_deriv(zbuf[slot].astype(jnp.float32), act)
    go_ref[...] = maybe_kq(go, g_bits)

    acc_ref[...] += jax.lax.dot_general(
        xbuf[slot].astype(jnp.float32), g, _XG_DIMS,
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        w = w_ref[...].astype(jnp.float32)
        wo_ref[...] = maybe_kq(w - lr_ref[0] * acc_ref[...], w_out_bits)


def _kernel_db_int8(g_hbm, w_ref, x_hbm, z_hbm, meta_ref, go_ref, wo_ref,
                    gbuf, xbuf, zbuf, acc_ref, wq_ref, sw_ref, sem, *,
                    n_k: int, bt: int, g_bits, w_bits, w_out_bits, act: str,
                    w_spec_static):
    k = pl.program_id(0)
    dmas = _db_dmas(g_hbm, x_hbm, z_hbm, gbuf, xbuf, zbuf, sem, bt)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        w = w_ref[...].astype(jnp.float32)
        if w_spec_static is not None:
            s_w = jnp.float32(w_spec_static.scale)
            wq_ref[...] = jnp.clip(jnp.round(w / s_w), w_spec_static.qmin,
                                   w_spec_static.qmax).astype(jnp.int8)
        else:
            am = jnp.max(jnp.abs(w))
            s_w = jnp.where(am > 0, am / 127.0, jnp.float32(1.0))
            wq_ref[...] = jnp.clip(jnp.round(w / s_w), -127,
                                   127).astype(jnp.int8)
        sw_ref[0, 0] = s_w

    slot = db_step(k, n_k, dmas)

    go = (int8_dot(gbuf[slot], wq_ref[...], _GW_DIMS).astype(jnp.float32)
          * (meta_ref[0] * sw_ref[0, 0]))
    go = go * act_deriv(zbuf[slot].astype(jnp.float32), act)
    go_ref[...] = maybe_kq(go, g_bits)

    acc_ref[...] += int8_dot(xbuf[slot], gbuf[slot], _XG_DIMS)

    @pl.when(k == n_k - 1)
    def _finish():
        dw = acc_ref[...].astype(jnp.float32) * meta_ref[1]
        wo_ref[...] = maybe_kq(w_ref[...].astype(jnp.float32)
                               - meta_ref[2] * dw, w_out_bits)


def bp_fused_unit(g: jax.Array, w: jax.Array, x: jax.Array, z: jax.Array,
                  lr, *, g_bits=(2, 12), w_bits=(2, 12), w_out_bits=None,
                  act: str = "relu", bt: int = 128,
                  interpret: bool = False,
                  datapath: str = "emulate",
                  g_scale: Optional[jax.Array] = None,
                  x_scale: Optional[jax.Array] = None,
                  double_buffer: bool = False):
    """One TDM frame.  g: [T, Dout] (dE/dZ_i); w: [Din, Dout] f32 master;
    x: [T, Din] (layer input X_{i-1}); z: [T, Din] (upstream pre-activation).

    Returns (G_out [T, Din] f32, W_new [Din, Dout] f32).

    int8 datapath: g/x are int8 payloads with scales (g_scale, x_scale);
    w stays the f32 master and is re-quantized to int8 in-kernel from the
    static ``w_bits`` format for the G product.
    double_buffer: explicit 2-slot DMA prefetch of the G/X/Z token blocks.
    """
    t, dout = g.shape
    din, dout2 = w.shape
    assert dout == dout2 and x.shape == (t, din) and z.shape == (t, din)
    bt = min(bt, t)
    assert t % bt == 0, (t, bt)
    n_k = t // bt

    grid = (n_k,)
    g_spec = pl.BlockSpec((bt, dout), lambda k: (k, 0))
    w_spec = pl.BlockSpec((din, dout), lambda k: (0, 0))
    x_spec = pl.BlockSpec((bt, din), lambda k: (k, 0))
    z_spec = pl.BlockSpec((bt, din), lambda k: (k, 0))
    go_spec = pl.BlockSpec((bt, din), lambda k: (k, 0))
    wo_spec = pl.BlockSpec((din, dout), lambda k: (0, 0))
    any_spec = pl.BlockSpec(memory_space=pl.ANY)
    out_shape = [jax.ShapeDtypeStruct((t, din), jnp.float32),
                 jax.ShapeDtypeStruct((din, dout), jnp.float32)]
    params = pltpu.CompilerParams(dimension_semantics=("arbitrary",))

    if double_buffer:
        # slots keep each operand's own dtype; the kernel bodies cast where
        # the implicit-pipeline kernels do, so numerics match exactly
        db_scratch = [pltpu.VMEM((2, bt, dout), g.dtype),   # G slots
                      pltpu.VMEM((2, bt, din), x.dtype),    # X slots
                      pltpu.VMEM((2, bt, din), z.dtype)]    # Z slots
        db_sem = [pltpu.SemaphoreType.DMA((3, 2))]

    if datapath == "int8":
        assert g.dtype == jnp.int8 and x.dtype == jnp.int8, (g.dtype, x.dtype)
        assert g_scale is not None and x_scale is not None
        # W embeds on its static (I,F) grid only when that fits int8; a
        # wider/absent format uses in-kernel absmax (block-scaled transport)
        spec = int8_spec(*w_bits) if w_bits is not None else None
        if spec is not None and not spec.exact:
            spec = None
        g_s = jnp.asarray(g_scale, jnp.float32)
        x_s = jnp.asarray(x_scale, jnp.float32)
        meta = jnp.stack([g_s,                             # s_g (s_w in-kernel)
                          x_s * g_s,                       # dW scale
                          jnp.asarray(lr, jnp.float32)])
        if double_buffer:
            return pl.pallas_call(
                functools.partial(_kernel_db_int8, n_k=n_k, bt=bt,
                                  g_bits=g_bits, w_bits=w_bits,
                                  w_out_bits=w_out_bits, act=act,
                                  w_spec_static=spec),
                grid=grid,
                in_specs=[any_spec, w_spec, any_spec, any_spec, any_spec],
                out_specs=[go_spec, wo_spec],
                out_shape=out_shape,
                scratch_shapes=db_scratch
                + [pltpu.VMEM((din, dout), jnp.int32),
                   pltpu.VMEM((din, dout), jnp.int8),
                   pltpu.VMEM((1, 1), jnp.float32)] + db_sem,
                compiler_params=params, interpret=interpret,
            )(g, w, x, z, meta)
        return pl.pallas_call(
            functools.partial(_kernel_int8, n_k=n_k, g_bits=g_bits,
                              w_bits=w_bits, w_out_bits=w_out_bits, act=act,
                              w_spec_static=spec),
            grid=grid,
            in_specs=[g_spec, w_spec, x_spec, z_spec, any_spec],
            out_specs=[go_spec, wo_spec],
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((din, dout), jnp.int32),
                            pltpu.VMEM((din, dout), jnp.int8),
                            pltpu.VMEM((1, 1), jnp.float32)],
            compiler_params=params, interpret=interpret,
        )(g, w, x, z, meta)

    assert datapath == "emulate", datapath
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    if double_buffer:
        return pl.pallas_call(
            functools.partial(_kernel_db, n_k=n_k, bt=bt, g_bits=g_bits,
                              w_bits=w_bits, w_out_bits=w_out_bits, act=act),
            grid=grid,
            in_specs=[any_spec, w_spec, any_spec, any_spec, any_spec],
            out_specs=[go_spec, wo_spec],
            out_shape=out_shape,
            scratch_shapes=db_scratch
            + [pltpu.VMEM((din, dout), jnp.float32),
               pltpu.VMEM((din, dout), jnp.float32)] + db_sem,
            compiler_params=params, interpret=interpret,
        )(g, w, x, z, lr_arr)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, g_bits=g_bits, w_bits=w_bits,
                          w_out_bits=w_out_bits, act=act),
        grid=grid,
        in_specs=[g_spec, w_spec, x_spec, z_spec, any_spec],
        out_specs=[go_spec, wo_spec],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((din, dout), jnp.float32),
                        pltpu.VMEM((din, dout), jnp.float32)],
        compiler_params=params, interpret=interpret,
    )(g, w, x, z, lr_arr)
