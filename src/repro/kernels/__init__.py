"""TaxoNN Pallas kernels: the paper's SGD-unit datapath on the MXU.

Four fused kernels (each with a f32-emulation and an int8-MXU datapath):
  fxp_matmul    — forward PE op  y = f(q_a(X) @ q_w(W))
  bp_gstep      — Eq. 8 G-chain step (backward matmul + derivation unit)
  sgd_dw_update — Eq. 9 outer product fused with the Eq. 1 weight update
  bp_fused_unit — the full TDM frame (Eq. 8 + Eq. 9 + Eq. 1 in one pass)

``ops`` holds the jit'd wrappers, the block autotuner, and the
``KernelBackend`` knob that wires these into the train/serve hot paths.
``ref`` holds the pure-jnp oracles (the correctness contract).
"""
from repro.kernels.bp_fused_unit import bp_fused_unit
from repro.kernels.bp_gstep import bp_gstep
from repro.kernels.fxp_matmul import fxp_matmul
from repro.kernels.sgd_dw_update import sgd_dw_update
from repro.kernels.ops import (
    KERNEL_BACKENDS,
    bp_fused_unit_op,
    bp_gstep_op,
    current_backend,
    fxp_matmul_op,
    kernel_backend_ctx,
    resolve_backend,
    sgd_dw_update_op,
    tune_blocks,
    tune_fused,
)

__all__ = [
    "bp_fused_unit", "bp_gstep", "fxp_matmul", "sgd_dw_update",
    "bp_fused_unit_op", "bp_gstep_op", "fxp_matmul_op", "sgd_dw_update_op",
    "KERNEL_BACKENDS", "kernel_backend_ctx", "current_backend",
    "resolve_backend", "tune_blocks", "tune_fused",
]
