"""Shared helpers for the TaxoNN Pallas kernels.

In-kernel fixed-point quantization (pure ops — no custom_vjp: the TaxoNN
engine owns gradients explicitly, kernels are forward pieces) and the
activation-derivative unit (the paper's f' hardware block).

TPU notes: block shapes are chosen 128-aligned for the MXU; accumulation is
f32 in VMEM (the paper's wide accumulator registers).  On real TPU the
(I,F)<=8-bit formats map to the int8 MXU path; this emulation computes the
same values in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def db_step(k, n_k: int, dmas):
    """One step of the shared double-buffer protocol: start the k==0
    copies, prefetch block k+1 into the other slot, wait on block k's, and
    return the slot (k % 2) the caller should consume.  ``dmas`` is a
    sequence of ``dma(slot, kk)`` constructors (one per streamed operand);
    the copy started here at step k is the one waited at step k+1, giving
    one grid step of DMA/compute overlap per operand."""
    @pl.when(k == 0)
    def _first():
        for d in dmas:
            d(0, 0).start()

    @pl.when(k + 1 < n_k)
    def _prefetch():
        nxt = (k + 1) % 2
        for d in dmas:
            d(nxt, k + 1).start()

    slot = k % 2
    for d in dmas:
        d(slot, k).wait()
    return slot


def kq(x, i_bits: int, f_bits: int):
    """Round-to-nearest fixed-point quantize (static bits inside a kernel)."""
    step = jnp.float32(2.0 ** (-f_bits))
    qmax = jnp.float32(2.0 ** (i_bits + f_bits) - 1)
    qmin = jnp.float32(-(2.0 ** (i_bits + f_bits)))
    k = jnp.clip(jnp.round(x.astype(jnp.float32) / step), qmin, qmax)
    return k * step


def maybe_kq(x, bits):
    """kq with ``bits=None`` meaning passthrough (unquantized datapath)."""
    return x if bits is None else kq(x, *bits)


def int8_dot(a, b, dims=None):
    """int8 x int8 -> int32 MAC: the MXU low-bit path (paper's PE array).

    ``dims`` follows ``lax.dot_general`` dimension_numbers; default is a
    plain [M,K]x[K,N] matmul.  Accumulation is exact int32 (the paper's
    wide accumulator registers — no rounding until the final rescale).
    """
    if dims is None:
        return jnp.dot(a, b, preferred_element_type=jnp.int32)
    return jax.lax.dot_general(a, b, dims, preferred_element_type=jnp.int32)


_GELU_C = 0.7978845608028654  # sqrt(2/pi)
_GELU_A = 0.044715


def act_fn(z, kind: str):
    if kind == "relu":
        return jnp.maximum(z, 0.0)
    if kind == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-z))
    if kind == "tanh":
        return jnp.tanh(z)
    if kind == "silu":
        return z / (1.0 + jnp.exp(-z))
    if kind == "gelu":  # tanh approximation (matches jax.nn.gelu approximate)
        return 0.5 * z * (1.0 + jnp.tanh(_GELU_C * (z + _GELU_A * z * z * z)))
    if kind == "identity":
        return z
    raise ValueError(kind)


def act_deriv(z, kind: str):
    """The paper's activation-derivation unit: f'(z) from the pre-activation.

    sigma' = sigma(1-sigma); tanh' = 4*sigma'(2z); relu' = step(z)."""
    if kind == "relu":
        return (z > 0).astype(jnp.float32)
    if kind == "sigmoid":
        s = 1.0 / (1.0 + jnp.exp(-z))
        return s * (1.0 - s)
    if kind == "tanh":
        t = jnp.tanh(z)
        return 1.0 - t * t
    if kind == "silu":
        s = 1.0 / (1.0 + jnp.exp(-z))
        return s * (1.0 + z * (1.0 - s))
    if kind == "gelu":
        u = _GELU_C * (z + _GELU_A * z * z * z)
        t = jnp.tanh(u)
        du = _GELU_C * (1.0 + 3.0 * _GELU_A * z * z)
        return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du
    if kind == "identity":
        return jnp.ones_like(z)
    raise ValueError(kind)
