"""Public entry points for the TaxoNN Pallas kernels.

Three layers live here:

  * ``KernelBackend`` — the trace-time knob selecting the datapath for the
    training/serving hot paths: ``"off"`` (pure jnp, the pre-kernel
    behaviour), ``"emulate"`` (Pallas kernels, f32 (I,F) emulation), and
    ``"int8"`` (int8 MXU operands with int32 wide accumulators).  ``"auto"``
    resolves to "off" on CPU and "int8" on TPU.  Installed with
    ``kernel_backend_ctx`` and read by ``models.layers.dense_unit``,
    ``core.steps.make_train_step`` and ``serving.engine.prefill``.

  * A small **autotuner** (``tune_blocks``) replacing the old power-of-two
    halving ``_pick``: it enumerates MXU-aligned candidate blocks (>= 8,
    sublane/lane friendly) that divide the operand dims, estimates the VMEM
    footprint (double-buffered inputs + output + accumulator), and keeps
    the 128-aligned choice with the largest tile volume under the budget.
    Choices are cached per (shape, itemsize).  When a dim has **no**
    aligned divisor >= 8 (odd/prime dims — the old code degraded to
    pathological 1-wide grids), it returns None and every wrapper falls
    back to the jnp oracle in ``ref.py``.

  * Jit'd wrappers (``*_op``) with ``interpret=True`` on CPU and
    Mosaic-compiled kernels on TPU, plus the ``dense_*`` helpers that the
    ``custom_vjp`` dense unit builds its forward/backward from (operand
    quantization with traced absmax scales on the int8 path).

Each streaming kernel also has an explicit **double-buffered DMA** datapath
(``double_buffer=``): operands stay in HBM and the grid body prefetches
block k+1 into the second slot of a 2-deep VMEM scratch while the MXU
consumes block k (bit-identical numerics; see fxp_matmul's docstring).
``resolve_double_buffer`` picks the platform default — ON for compiled TPU
kernels, OFF under CPU interpret mode.  The wrappers always call the
autotuner with its 2-slot budget because BOTH fetch mechanisms hold two
blocks resident (Pallas' implicit pipeline is itself 2-deep);
``tune_blocks(double_buffer=False)`` models a hypothetical single-buffered
fetch, not a wrapper path.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bp_fused_unit import bp_fused_unit
from repro.kernels.bp_gstep import bp_gstep
from repro.kernels.common import int8_dot
from repro.kernels.fxp_matmul import fxp_matmul
from repro.kernels.sgd_dw_update import sgd_dw_update
from repro.quant.int8 import quantize_int8_absmax, quantize_int8_auto


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# KernelBackend knob
# ---------------------------------------------------------------------------

KERNEL_BACKENDS = ("off", "emulate", "int8")

_BACKEND: contextvars.ContextVar[str] = contextvars.ContextVar(
    "kernel_backend", default="off")


def resolve_backend(backend: Optional[str]) -> str:
    """Resolve ``None``/"auto" to the platform default (off on CPU — the
    interpreter-mode kernels would only slow tests down — int8 on TPU)."""
    if backend is None or backend == "auto":
        return "off" if _on_cpu() else "int8"
    if backend not in KERNEL_BACKENDS:
        raise ValueError(
            f"kernel_backend {backend!r} not in {KERNEL_BACKENDS + ('auto',)}")
    return backend


@contextlib.contextmanager
def kernel_backend_ctx(backend: Optional[str]):
    """Install a kernel backend for the enclosed trace (like perf options)."""
    token = _BACKEND.set(resolve_backend(backend))
    try:
        yield
    finally:
        _BACKEND.reset(token)


def current_backend() -> str:
    return _BACKEND.get()


def resolve_double_buffer(double_buffer: Optional[bool] = None) -> bool:
    """Resolve the explicit prefetch-DMA datapath knob.

    ``None`` picks the platform default: ON for compiled TPU kernels (the
    DMAs genuinely overlap the MXU), OFF on CPU where the interpreter
    would only emulate the copies serially.  Deterministic per process, so
    it is safe to consult inside jit-traced wrapper bodies.
    """
    if double_buffer is None:
        return not _on_cpu()
    return bool(double_buffer)


# ---------------------------------------------------------------------------
# Block autotuner + persistent tune cache
# ---------------------------------------------------------------------------
#
# Tuning decisions used to live in per-function ``lru_cache`` state — gone
# at process exit, re-derived (and in principle re-derivable DIFFERENTLY
# after a budget tweak) on every restart.  They are now rows in one
# process-wide ``_TUNE_CACHE`` dict with the transport cache's lifecycle
# (dist.async_collectives): prime at driver start-up from the active
# model's shapes, ``tune_cache_snapshot()`` into checkpoint/serve-snapshot
# ``extra``, ``load_tune_cache()`` on restore (no-clobber, ``restored:``
# provenance), ``dump_tune_cache()``/``REPRO_TUNE_CACHE`` for the on-disk
# artifact — so a resumed run replays the original run's block choices
# instead of re-deriving them.

VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # half of a ~16MB VMEM core
_MAX_BLOCK = 2048

# (kind, *int_args) -> {"decision": tuple | int | None, "source": str}
_TUNE_CACHE: dict = {}
_TUNE_ENV_LOADED = False

# snapshot-key field names per decision kind, in tuner-argument order
_TUNE_FIELDS = {
    "blocks": ("m", "n", "k", "item", "acc", "db"),
    "fused": ("t", "din", "dout", "item", "acc", "db"),
    "paged": ("n", "bs", "m", "hkv", "hd", "g", "item"),
    "prologue": ("d", "h", "hkv", "hd", "item"),
}


def _maybe_load_env_cache() -> None:
    """One-shot lazy load of REPRO_TUNE_CACHE (a dump_tune_cache file)."""
    global _TUNE_ENV_LOADED
    if _TUNE_ENV_LOADED:
        return
    _TUNE_ENV_LOADED = True
    path = os.environ.get("REPRO_TUNE_CACHE", "").strip()
    if path:
        with open(path) as f:
            snap = json.load(f)
        n = load_tune_cache(snap)
        print(f"[kernels] loaded {n} tune-cache decision(s) from {path}",
              flush=True)


def _tune_lookup(kind: str, args: tuple):
    _maybe_load_env_cache()
    return _TUNE_CACHE.get((kind,) + args)


def _tune_record(kind: str, args: tuple, decision):
    _TUNE_CACHE[(kind,) + args] = {"decision": decision, "source": "computed"}
    return decision


def _candidates(dim: int) -> list:
    """Sublane-aligned blocks (multiples of 8) dividing ``dim``, descending.
    Empty when no aligned block >= 8 divides the dim (odd/prime shapes)."""
    start = (min(dim, _MAX_BLOCK) // 8) * 8
    return [b for b in range(start, 7, -8) if dim % b == 0]


def tune_blocks(m: int, n: int, k: int, itemsize: int = 4,
                acc_itemsize: int = 4,
                double_buffer: bool = True) -> Optional[tuple]:
    """Pick (bm, bn, bk) for a [m,k]x[k,n]-shaped kernel grid.

    ``double_buffer`` budgets TWO VMEM slots per streamed input block —
    both for Pallas' implicit pipeline and for the explicit prefetch-DMA
    datapath (``double_buffer=True`` on the kernels), which hold block k
    and block k+1 resident simultaneously.  ``False`` models a
    single-buffered fetch (no overlap) and admits ~2x larger tiles.

    Returns None when some dim has no aligned divisor >= 8 — callers fall
    back to the jnp reference path instead of degrading to 1-wide blocks.
    Decisions persist in the tune cache (restored entries win).
    """
    args = (int(m), int(n), int(k), int(itemsize), int(acc_itemsize),
            bool(double_buffer))
    hit = _tune_lookup("blocks", args)
    if hit is not None:
        d = hit["decision"]
        return None if d is None else tuple(d)
    cm, cn, ck = _candidates(m), _candidates(n), _candidates(k)
    if not (cm and cn and ck):
        return _tune_record("blocks", args, None)
    slots = 2 if double_buffer else 1
    best, best_key = None, None
    for bm in cm:
        for bn in cn:
            for bk in ck:
                # slotted input blocks + resident output + accumulator
                vmem = (slots * (bm * bk + bk * bn) * itemsize
                        + bm * bn * (4 + acc_itemsize))
                if vmem > VMEM_BUDGET_BYTES:
                    continue
                mxu = sum(b % 128 == 0 or b == full
                          for b, full in ((bm, m), (bn, n), (bk, k)))
                key = (mxu, bm * bn * bk, min(bm, bn))
                if best_key is None or key > best_key:
                    best, best_key = (bm, bn, bk), key
    return _tune_record("blocks", args, best)


def tune_paged(num_blocks: int, block_size: int, max_blocks_per_seq: int,
               kv_heads: int, head_dim: int, groups: int,
               itemsize: int = 4) -> Optional[int]:
    """VMEM budget for the paged-attention kernel (sibling of
    ``tune_blocks``, same 8MB budget): the block pool stays resident in
    VMEM while each grid step gathers + dequantizes one slot's blocks into
    a [max_blocks*block, kv_heads, head_dim] scratch and runs the fused
    softmax over the expanded heads.  Returns the resident byte count when
    the kernel fits, None -> callers fall back to the jnp gather path.
    """
    args = (int(num_blocks), int(block_size), int(max_blocks_per_seq),
            int(kv_heads), int(head_dim), int(groups), int(itemsize))
    hit = _tune_lookup("paged", args)
    if hit is not None:
        return hit["decision"]
    if block_size < 1 or head_dim % 8 != 0:
        return _tune_record("paged", args, None)
    t = max_blocks_per_seq * block_size
    pool = 2 * num_blocks * block_size * kv_heads * head_dim * itemsize
    if itemsize == 1:  # int8 payload rides with per-token f32 scales
        pool += 2 * num_blocks * block_size * 4
    gathered = 2 * t * kv_heads * head_dim * 4
    scores = (kv_heads * groups) * t * 4
    total = pool + gathered + scores
    return _tune_record("paged", args,
                        total if total <= VMEM_BUDGET_BYTES else None)


def tune_fused(t: int, din: int, dout: int, itemsize: int = 4,
               acc_itemsize: int = 4,
               double_buffer: bool = True) -> Optional[int]:
    """Token-block size for bp_fused_unit (W + dW accumulator stay resident);
    None when the frame cannot fit VMEM or t has no aligned divisor.
    ``double_buffer`` budgets the second G/X/Z streaming slot."""
    args = (int(t), int(din), int(dout), int(itemsize), int(acc_itemsize),
            bool(double_buffer))
    hit = _tune_lookup("fused", args)
    if hit is not None:
        return hit["decision"]
    ct = _candidates(t)
    if not ct or not _candidates(din) or not _candidates(dout):
        return _tune_record("fused", args, None)
    slots = 2 if double_buffer else 1
    # W (f32) + dW accumulator + the cached q_w(W) scratch
    resident = din * dout * (4 + acc_itemsize + itemsize)
    for bt in ct:
        stream = (slots * (bt * dout + 2 * bt * din) * itemsize
                  + slots * bt * din * 4)
        if resident + stream <= VMEM_BUDGET_BYTES:
            return _tune_record("fused", args, bt)
    return _tune_record("fused", args, None)


def tune_prologue(d: int, h: int, hkv: int, hd: int,
                  itemsize: int = 4) -> Optional[int]:
    """VMEM budget for the fused decode-prologue kernel
    (``kernels.decode_prologue``): the QKV weights stay resident while each
    grid step norms one token's residual row and runs the three projections
    + rope in place.  ``itemsize`` is the weight payload size (1 on the
    int8 datapath, whose f32 scales are scalars).  Returns the resident
    byte count when the frame fits, None -> callers fall back to the
    jitted jnp reference (the contract twin — bit-identical either way).
    """
    args = (int(d), int(h), int(hkv), int(hd), int(itemsize))
    hit = _tune_lookup("prologue", args)
    if hit is not None:
        return hit["decision"]
    if d % 8 != 0 or hd % 8 != 0:
        return _tune_record("prologue", args, None)
    weights = d * (h + 2 * hkv) * hd * itemsize
    row = 2 * d * 4                       # x row + normed row, f32
    outs = (h + 2 * hkv) * hd * 4         # q/k/v rows for one token
    rope = hd * 4                         # cos/sin working set
    total = weights + row + outs + rope
    return _tune_record("prologue", args,
                        total if total <= VMEM_BUDGET_BYTES else None)


# ---------------------------------------------------------------------------
# Tune-cache persistence (the transport cache's snapshot/load/provenance
# API, applied to kernel tuning decisions)
# ---------------------------------------------------------------------------

def tune_cache_snapshot() -> dict:
    """Copy of the decision cache with JSON-friendly keys, e.g.
    ``"kind=blocks,m=256,n=256,k=256,item=4,acc=4,db=True"``."""
    _maybe_load_env_cache()
    snap = {}
    for key in sorted(_TUNE_CACHE, key=repr):
        kind, args = key[0], key[1:]
        fields = _TUNE_FIELDS[kind]
        skey = ",".join(["kind=" + kind]
                        + [f"{f}={a}" for f, a in zip(fields, args)])
        ent = _TUNE_CACHE[key]
        d = ent["decision"]
        snap[skey] = {"decision": list(d) if isinstance(d, tuple) else d,
                      "source": ent["source"]}
    return snap


def dump_tune_cache(path: str) -> None:
    """Persist the decision cache (the CI bench uploads it next to
    ``transport_cache.fresh.json``; point REPRO_TUNE_CACHE at the file to
    preload a later process)."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(tune_cache_snapshot(), f, indent=2, sort_keys=True)


def load_tune_cache(snapshot: dict, *, overwrite: bool = False) -> int:
    """Inverse of ``tune_cache_snapshot``: install persisted decisions
    (e.g. from a checkpoint's resume ``extra`` or a serve snapshot) so a
    RESUMED run replays the original run's block choices instead of
    re-deriving them.  Existing entries win unless ``overwrite``; restored
    rows carry ``restored:<original source>`` provenance.  Returns the
    number of entries installed; malformed entries are skipped."""
    n = 0
    for skey, entry in (snapshot or {}).items():
        try:
            parts = dict(p.split("=", 1) for p in skey.split(","))
            kind = parts.pop("kind")
            fields = _TUNE_FIELDS[kind]
            args = tuple(parts[f] == "True" if f == "db" else int(parts[f])
                         for f in fields)
            d = entry["decision"]
            if isinstance(d, (list, tuple)):
                d = tuple(int(v) for v in d)
            elif d is not None:
                d = int(d)
            source = f"restored:{entry.get('source', '?')}"
        except (KeyError, ValueError, AttributeError, TypeError):
            continue
        key = (kind,) + args
        if not overwrite and key in _TUNE_CACHE:
            continue
        _TUNE_CACHE[key] = {"decision": d, "source": source}
        n += 1
    return n


def clear_tune_cache() -> None:
    _TUNE_CACHE.clear()


def prime_tune_cache(shapes: dict) -> dict:
    """Eagerly derive + cache the decisions a run will need (call at driver
    start-up, after any checkpoint restore: restored entries are cache hits
    and are NOT re-derived).  ``shapes`` maps kind -> iterable of tuner
    argument tuples, e.g. ``{"blocks": [(4096, 11008, 4096, 1)], "paged":
    [...]}``.  Returns {snapshot-key: decision} for the primed entries."""
    tuners = {"blocks": tune_blocks, "fused": tune_fused,
              "paged": tune_paged, "prologue": tune_prologue}
    out = {}
    for kind, arg_tuples in shapes.items():
        fn = tuners[kind]
        for args in arg_tuples:
            decision = fn(*args)
            fields = _TUNE_FIELDS[kind]
            skey = ",".join(["kind=" + kind]
                            + [f"{f}={a}" for f, a in zip(fields, args)])
            out[skey] = decision
    return out


def train_tune_shapes(cfg, global_batch: int, seq_len: int) -> dict:
    """The ``prime_tune_cache`` shape set a train run's hot matmuls hit:
    MLP up/down, QKV/output projections and the fused TDM frame at
    t = batch * seq tokens, on both datapaths (f32 and int8 payloads)."""
    t = int(global_batch) * int(seq_len)
    d = int(cfg.d_model)
    ff = int(cfg.d_ff or cfg.moe_d_ff or 0)
    pairs = []
    if ff:
        pairs += [(t, ff, d), (t, d, ff)]
    if cfg.num_heads:
        hw = int((cfg.padded_heads or cfg.num_heads) * cfg.head_dim)
        pairs += [(t, hw, d), (t, d, hw)]
    shapes = {"blocks": [], "fused": []}
    for (m, n, k) in pairs:
        for item in (1, 4):
            shapes["blocks"].append((m, n, k, item))
    if ff:
        for item in (1, 4):
            shapes["fused"].append((t, d, ff, item))
    return shapes


def serve_tune_shapes(cfg, *, num_blocks: int, block_size: int,
                      max_blocks_per_seq: int, cache_itemsize: int = 4) -> dict:
    """The ``prime_tune_cache`` shape set the paged serving path hits: the
    paged-attention gather budget for the configured pool and the decode
    prologue at this model's head geometry (both datapaths)."""
    d = int(cfg.d_model)
    h = int(cfg.padded_heads or cfg.num_heads)
    hkv = int(cfg.num_kv_heads)
    hd = int(cfg.head_dim)
    groups = max(1, h // max(hkv, 1))
    return {
        "paged": [(int(num_blocks), int(block_size), int(max_blocks_per_seq),
                   hkv, hd, groups, int(cache_itemsize))],
        "prologue": [(d, h, hkv, hd, 4), (d, h, hkv, hd, 1)],
    }


# ---------------------------------------------------------------------------
# Jit'd wrappers (ref fallback on untileable shapes)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "xa_bits", "w_bits", "out_bits", "act", "datapath", "double_buffer"))
def fxp_matmul_op(x, w, *, xa_bits=(4, 10), w_bits=(2, 12),
                  out_bits=(4, 10), act="identity", datapath="emulate",
                  double_buffer=None):
    m, k = x.shape
    n = w.shape[1]
    db = resolve_double_buffer(double_buffer)
    blocks = tune_blocks(m, n, k, itemsize=1 if datapath == "int8" else 4)
    if datapath == "int8":
        if blocks is None:
            return ref.fxp_matmul_int8_ref(x, w, xa_bits=xa_bits,
                                           w_bits=w_bits, out_bits=out_bits,
                                           act=act)
        qx, sx = quantize_int8_auto(x, xa_bits)
        qw, sw = quantize_int8_auto(w, w_bits)
        bm, bn, bk = blocks
        return fxp_matmul(qx, qw, out_bits=out_bits, act=act,
                          bm=bm, bn=bn, bk=bk, datapath="int8",
                          scale=sx * sw, interpret=_on_cpu(),
                          double_buffer=db)
    if blocks is None:
        return ref.fxp_matmul_ref(x, w, xa_bits=xa_bits, w_bits=w_bits,
                                  out_bits=out_bits, act=act)
    bm, bn, bk = blocks
    return fxp_matmul(x, w, xa_bits=xa_bits, w_bits=w_bits,
                      out_bits=out_bits, act=act,
                      bm=bm, bn=bn, bk=bk, interpret=_on_cpu(),
                      double_buffer=db)


@functools.partial(jax.jit, static_argnames=(
    "g_bits", "act", "datapath", "g_in_bits", "w_bits", "double_buffer"))
def bp_gstep_op(g, w, z, *, g_bits=(2, 12), act="relu", datapath="emulate",
                g_in_bits=(2, 12), w_bits=(2, 12), double_buffer=None):
    t, dout = g.shape
    din = w.shape[0]
    db = resolve_double_buffer(double_buffer)
    blocks = tune_blocks(t, din, dout, itemsize=1 if datapath == "int8" else 4)
    if datapath == "int8":
        if blocks is None:
            return ref.bp_gstep_int8_ref(g, w, z, g_in_bits=g_in_bits,
                                         w_bits=w_bits, g_bits=g_bits, act=act)
        qg, sg = quantize_int8_auto(g, g_in_bits)
        qw, sw = quantize_int8_auto(w, w_bits)
        bm, bn, bk = blocks
        return bp_gstep(qg, qw, z, g_bits=g_bits, act=act,
                        bm=bm, bn=bn, bk=bk, datapath="int8",
                        scale=sg * sw, interpret=_on_cpu(),
                        double_buffer=db)
    if blocks is None:
        return ref.bp_gstep_ref(g, w, z, g_bits=g_bits, act=act)
    bm, bn, bk = blocks
    return bp_gstep(g, w, z, g_bits=g_bits, act=act,
                    bm=bm, bn=bn, bk=bk, interpret=_on_cpu(),
                    double_buffer=db)


@functools.partial(jax.jit, static_argnames=(
    "w_bits", "datapath", "xa_bits", "g_in_bits"))
def sgd_dw_update_op(x, g, w, lr, *, w_bits=None, datapath="emulate",
                     xa_bits=(4, 10), g_in_bits=(2, 12)):
    t, din = x.shape
    dout = g.shape[1]
    blocks = tune_blocks(din, dout, t, itemsize=1 if datapath == "int8" else 4)
    if datapath == "int8":
        if blocks is None:
            return ref.sgd_dw_update_int8_ref(x, g, w, lr, xa_bits=xa_bits,
                                              g_in_bits=g_in_bits,
                                              w_bits=w_bits)
        qx, sx = quantize_int8_auto(x, xa_bits)
        qg, sg = quantize_int8_auto(g, g_in_bits)
        bm, bn, bk = blocks
        return sgd_dw_update(qx, qg, w, lr, w_bits=w_bits,
                             bm=bm, bn=bn, bk=bk, datapath="int8",
                             scale=sx * sg, interpret=_on_cpu())
    if blocks is None:
        return ref.sgd_dw_update_ref(x, g, w, lr, w_bits=w_bits)
    bm, bn, bk = blocks
    return sgd_dw_update(x, g, w, lr, w_bits=w_bits,
                         bm=bm, bn=bn, bk=bk, interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=(
    "g_bits", "w_bits", "w_out_bits", "act", "datapath", "g_in_bits",
    "xa_bits", "double_buffer"))
def bp_fused_unit_op(g, w, x, z, lr, *, g_bits=(2, 12), w_bits=(2, 12),
                     w_out_bits=None, act="relu", datapath="emulate",
                     g_in_bits=(2, 12), xa_bits=(4, 10), double_buffer=None):
    """One TDM frame (see bp_fused_unit); falls back to the sequential jnp
    oracle when the frame cannot be tiled/fit."""
    t, dout = g.shape
    din = w.shape[0]
    db = resolve_double_buffer(double_buffer)
    bt = tune_fused(t, din, dout, itemsize=1 if datapath == "int8" else 4)
    if datapath == "int8":
        if bt is None:
            return ref.bp_fused_unit_int8_ref(
                g, w, x, z, lr, g_in_bits=g_in_bits, xa_bits=xa_bits,
                g_bits=g_bits, w_bits=w_bits, w_out_bits=w_out_bits, act=act)
        qg, sg = quantize_int8_auto(g, g_in_bits)
        qx, sx = quantize_int8_auto(x, xa_bits)
        return bp_fused_unit(qg, w, qx, z, lr, g_bits=g_bits, w_bits=w_bits,
                             w_out_bits=w_out_bits, act=act, bt=bt,
                             datapath="int8", g_scale=sg, x_scale=sx,
                             interpret=_on_cpu(), double_buffer=db)
    if bt is None:
        return ref.bp_fused_unit_ref(g, w, x, z, lr, g_bits=g_bits,
                                     w_bits=w_bits, w_out_bits=w_out_bits,
                                     act=act)
    return bp_fused_unit(g, w, x, z, lr, g_bits=g_bits, w_bits=w_bits,
                         w_out_bits=w_out_bits, act=act, bt=bt,
                         interpret=_on_cpu(), double_buffer=db)


# ---------------------------------------------------------------------------
# dense_unit building blocks (traced absmax scales; no in-kernel (I,F) —
# the engine's STE wrappers own the (I,F) grid on these paths)
# ---------------------------------------------------------------------------

def dense_fwd(x2, w, backend: str):
    """z = x2 @ w at f32 through the selected datapath. x2: [M,K], w: [K,N].

    Returns the raw pre-activation z — the caller applies the activation
    (and keeps z for the backward derivation unit).
    """
    m, k = x2.shape
    n = w.shape[1]
    if backend == "int8":
        qx, sx = quantize_int8_absmax(x2)
        qw, sw = quantize_int8_absmax(w)
        blocks = tune_blocks(m, n, k, itemsize=1)
        if blocks is None:
            return int8_dot(qx, qw).astype(jnp.float32) * (sx * sw)
        bm, bn, bk = blocks
        return fxp_matmul(qx, qw, out_bits=None, act="identity",
                          bm=bm, bn=bn, bk=bk, datapath="int8",
                          scale=sx * sw, interpret=_on_cpu(),
                          double_buffer=resolve_double_buffer())
    blocks = tune_blocks(m, n, k)
    if blocks is None:
        return jnp.dot(x2.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    bm, bn, bk = blocks
    return fxp_matmul(x2.astype(jnp.float32), w.astype(jnp.float32),
                      xa_bits=None, w_bits=None, out_bits=None,
                      act="identity", bm=bm, bn=bn, bk=bk,
                      interpret=_on_cpu(),
                      double_buffer=resolve_double_buffer())


def dense_bwd_dx(dz, w, backend: str):
    """dx = dz @ w^T via bp_gstep. dz: [M,N], w: [K,N]... note orientation:
    here w is [K, N] so bp_gstep's (g [T,Dout], w [Din,Dout]) maps to
    (dz [M,N], w [K,N]) -> [M,K]."""
    m, n = dz.shape
    k = w.shape[0]
    if backend == "int8":
        qg, sg = quantize_int8_absmax(dz)
        qw, sw = quantize_int8_absmax(w)
        blocks = tune_blocks(m, k, n, itemsize=1)
        if blocks is None:
            return int8_dot(qg, qw.T).astype(jnp.float32) * (sg * sw)
        bm, bn, bk = blocks
        return bp_gstep(qg, qw, None, g_bits=None, act="identity",
                        bm=bm, bn=bn, bk=bk, datapath="int8",
                        scale=sg * sw, interpret=_on_cpu(),
                        double_buffer=resolve_double_buffer())
    blocks = tune_blocks(m, k, n)
    if blocks is None:
        return jnp.dot(dz, w.astype(jnp.float32).T,
                       preferred_element_type=jnp.float32)
    bm, bn, bk = blocks
    return bp_gstep(dz, w.astype(jnp.float32), None, g_bits=None,
                    act="identity", bm=bm, bn=bn, bk=bk,
                    interpret=_on_cpu(),
                    double_buffer=resolve_double_buffer())


def dense_bwd_dw(x2, dz, backend: str):
    """dw = x2^T @ dz via the dW-only form of sgd_dw_update."""
    m, k = x2.shape
    n = dz.shape[1]
    if backend == "int8":
        qx, sx = quantize_int8_absmax(x2)
        qg, sg = quantize_int8_absmax(dz)
        blocks = tune_blocks(k, n, m, itemsize=1)
        if blocks is None:
            return int8_dot(qx.T, qg).astype(jnp.float32) * (sx * sg)
        bm, bn, bk = blocks
        return sgd_dw_update(qx, qg, None, 0.0, bm=bm, bn=bn, bk=bk,
                             datapath="int8", scale=sx * sg,
                             interpret=_on_cpu())
    blocks = tune_blocks(k, n, m)
    if blocks is None:
        return jnp.dot(x2.astype(jnp.float32).T, dz,
                       preferred_element_type=jnp.float32)
    bm, bn, bk = blocks
    return sgd_dw_update(x2.astype(jnp.float32), dz, None, 0.0,
                         bm=bm, bn=bn, bk=bk, interpret=_on_cpu())
