"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU,
where the Mosaic-compiled kernels run natively.  The wrappers pick
MXU-aligned block sizes that divide the operand shapes.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.bp_gstep import bp_gstep
from repro.kernels.fxp_matmul import fxp_matmul
from repro.kernels.sgd_dw_update import sgd_dw_update


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pick(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=(
    "xa_bits", "w_bits", "out_bits", "act"))
def fxp_matmul_op(x, w, *, xa_bits=(4, 10), w_bits=(2, 12),
                  out_bits=(4, 10), act="identity"):
    m, k = x.shape
    n = w.shape[1]
    return fxp_matmul(
        x, w, xa_bits=xa_bits, w_bits=w_bits, out_bits=out_bits, act=act,
        bm=_pick(128, m), bn=_pick(128, n), bk=_pick(128, k),
        interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("g_bits", "act"))
def bp_gstep_op(g, w, z, *, g_bits=(2, 12), act="relu"):
    t, dout = g.shape
    din = w.shape[0]
    return bp_gstep(
        g, w, z, g_bits=g_bits, act=act,
        bm=_pick(128, t), bn=_pick(128, din), bk=_pick(128, dout),
        interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("w_bits",))
def sgd_dw_update_op(x, g, w, lr, *, w_bits=None):
    t, din = x.shape
    dout = g.shape[1]
    return sgd_dw_update(
        x, g, w, lr, w_bits=w_bits,
        bm=_pick(128, din), bn=_pick(128, dout), bk=_pick(128, t),
        interpret=_on_cpu())
