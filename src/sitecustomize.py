"""Auto-loaded by the interpreter when ``src`` is on PYTHONPATH.

Installs the jax API compatibility shims (see repro/util/jaxcompat.py)
before any test or launcher code imports jax mesh machinery.  Subprocess
tests (`python -c` with PYTHONPATH=src:tests) rely on this; in-process
pytest runs get the same shims via tests/conftest.py.
"""
try:
    import repro.util.jaxcompat  # noqa: F401
except Exception:  # pragma: no cover - never block interpreter start-up
    pass
